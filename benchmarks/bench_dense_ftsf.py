"""Paper Fig. 12: dense tensor — binary blob vs FTSF.

Scenario 1 (§V.A): FFHQ-like (N, 3, H, W) uint8 tensor. Baseline = one
serialized blob in the object store (numpy.save analog: raw C-order bytes).
FTSF = 3-D chunks (one per image) in the delta table. Metrics: storage
size, write, read-tensor, read-slice X[0:100] — compression ratio Cr and
the slice-read speedup are the paper's headline numbers.
"""

from __future__ import annotations

import numpy as np

from repro.configs.paper_store import PAPER_STORE
from repro.core import DeltaTensorStore
from repro.data.synthetic import ffhq_like
from repro.lake import ReadExecutor

from .common import fresh_store, row, timed

PARALLEL_WIDTH = 8


def run(shape=None, repeats=None):
    cfgd = PAPER_STORE["dense"]
    shape = shape or cfgd["bench_shape"]
    repeats = repeats or PAPER_STORE["repeats"]
    x = ffhq_like(shape)
    # paper slice is X[0:100] of 5000 images = 2% of the first dim
    sl_lo = 0
    sl_hi = max(1, int(shape[0] * 100 / 5000))

    out = []

    # --- binary baseline -----------------------------------------------------
    obj, lm = fresh_store()
    blob = x.tobytes()
    w = timed(lm, lambda: obj.put("blobs/x", x.tobytes()), repeats)
    size_binary = obj.head("blobs/x")

    def read_all_binary():
        raw = obj.get("blobs/x")
        np.frombuffer(raw, dtype=x.dtype).reshape(shape)

    r = timed(lm, read_all_binary, repeats)

    def read_slice_binary():  # must fetch the whole blob to slice it
        raw = obj.get("blobs/x")
        np.frombuffer(raw, dtype=x.dtype).reshape(shape)[sl_lo:sl_hi]

    s = timed(lm, read_slice_binary, repeats)
    out.append(("binary", size_binary, w, r, s))

    # --- FTSF (serial read path: executor width 1, no cache) -----------------
    obj, lm = fresh_store()
    store = DeltaTensorStore(obj, "tensors",
                             io=ReadExecutor(max_workers=1, cache_bytes=0))
    w2 = timed(lm, lambda: store.put(x, layout="ftsf", tensor_id="x",
                                     chunk_dims=cfgd["chunk_dims"],
                                     target_file_bytes=512 << 10,
                                     overwrite=True), repeats)
    size_ftsf = store.tensor_bytes("x")
    r2 = timed(lm, lambda: store.get("x"), repeats)
    s2 = timed(lm, lambda: store.get_slice("x", [(sl_lo, sl_hi)]), repeats)
    out.append(("ftsf", size_ftsf, w2, r2, s2))

    # --- FTSF parallel read path (width 8) + warm block cache ----------------
    obj_p, lm_p = fresh_store(parallelism=PARALLEL_WIDTH)
    store_p = DeltaTensorStore(
        obj_p, "tensors",
        io=ReadExecutor(max_workers=PARALLEL_WIDTH, cache_bytes=0))
    store_p.put(x, layout="ftsf", tensor_id="x", chunk_dims=cfgd["chunk_dims"],
                target_file_bytes=512 << 10, overwrite=True)
    r3 = timed(lm_p, lambda: store_p.get("x"), repeats)
    s3 = timed(lm_p, lambda: store_p.get_slice("x", [(sl_lo, sl_hi)]), repeats)

    obj_c, lm_c = fresh_store(parallelism=PARALLEL_WIDTH)
    store_c = DeltaTensorStore(
        obj_c, "tensors",
        io=ReadExecutor(max_workers=PARALLEL_WIDTH, cache_bytes=256 << 20))
    store_c.put(x, layout="ftsf", tensor_id="x", chunk_dims=cfgd["chunk_dims"],
                target_file_bytes=512 << 10, overwrite=True)
    store_c.get("x")                       # cold read warms the cache
    r4 = timed(lm_c, lambda: store_c.get("x"), repeats)

    cr = size_ftsf / size_binary
    lines = []
    for name, size, w_, r_, s_ in out:
        lines.append(row(f"dense_{name}_write", w_.total_s * 1e6,
                         f"size_bytes={size}"))
        lines.append(row(f"dense_{name}_read_tensor", r_.total_s * 1e6,
                         f"io_s={r_.io_s:.3f}"))
        lines.append(row(f"dense_{name}_read_slice", s_.total_s * 1e6,
                         f"bytes_moved={s_.bytes_moved}"))
    lines.append(row(f"dense_ftsf_read_tensor_w{PARALLEL_WIDTH}",
                     r3.total_s * 1e6, f"io_s={r3.io_s:.3f}"))
    lines.append(row(f"dense_ftsf_read_slice_w{PARALLEL_WIDTH}",
                     s3.total_s * 1e6, f"bytes_moved={s3.bytes_moved}"))
    lines.append(row("dense_ftsf_read_tensor_cached", r4.total_s * 1e6,
                     f"requests={lm_c.requests} bytes_moved={r4.bytes_moved}"))
    slice_delta = out[1][4].total_s / out[0][4].total_s - 1
    lines.append(row("dense_ftsf_summary", 0.0,
                     f"Cr={cr:.4f} (paper 0.9109); "
                     f"slice_delta={slice_delta:+.2%} (paper -90.04%); "
                     f"parallel_read_speedup={r2.io_s / max(r3.io_s, 1e-12):.2f}x"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
