"""§Roofline: three-term analysis per (arch × shape × mesh) from dry-run
artifacts (experiments/dryrun/*.json).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. The corrected (loop-aware) per-device HLO costs give:

  compute term    = flops / peak_flops
  memory term     = hbm_bytes / hbm_bw
  collective term = collective_bytes / link_bw

The bound step time is max(terms) (perfect-overlap assumption — XLA's
latency-hiding scheduler overlaps collectives with compute); the roofline
fraction = compute_term / bound, i.e. the share of the step the MXUs can be
busy. MODEL_FLOPS/HLO_FLOPs (analytic 6·N·D or 2·N·D vs compiled, per
device) catches remat/redundancy waste.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(mesh: str = "single", tag: str = "") -> List[Dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        rows.append(r)
    return rows


def terms(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok" or "corrected" not in rec:
        return None
    n_dev = rec["n_devices"]
    c = rec["corrected"]
    compute_s = c["flops"] / PEAK_FLOPS
    memory_s = c["bytes"] / HBM_BW
    coll_s = sum(c["coll_bytes"].values()) / LINK_BW
    bound = max(compute_s, memory_s, coll_s, 1e-12)
    dominant = ("compute" if bound == compute_s else
                "memory" if bound == memory_s else "collective")
    model_flops_dev = (rec["analytic"]["model_flops"] +
                       rec["analytic"]["attn_flops"]) / n_dev
    ratio = model_flops_dev / max(c["flops"], 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "bound_s": bound, "dominant": dominant,
        "fraction": compute_s / bound,
        "model_hlo_ratio": ratio,
        "hbm_per_dev_gb": (rec["memory"].get("temp_size_in_bytes") or 0) / 1e9,
        "note": rec.get("note", ""),
        "tag": rec.get("tag", ""),
    }


FIX_HINTS = {
    "compute": "already MXU-bound: raise MODEL/HLO ratio (less remat) or "
               "overlap the residual comm",
    "memory": "cut HBM traffic: looser remat policy (save dots), bf16 "
              "optimizer moments, fuse gather/scatter paths, donate caches",
    "collective": "cut wire bytes: reshard (2D sharding), reduce-scatter "
                  "instead of all-reduce, compress cross-pod gradients "
                  "(BSGS top-k), overlap via latency-hiding scheduler",
}


def table(mesh: str = "single", tag: str = "") -> List[Dict[str, Any]]:
    out = []
    for rec in load(mesh, tag):
        t = terms(rec)
        if t:
            out.append(t)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "dominant": "skipped",
                        "note": rec.get("reason", "")})
    return out


def markdown(rows: List[Dict[str, Any]]) -> str:
    lines = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
             "dominant | roofline frac | MODEL/HLO |",
             "|---|---|---|---|---|---|---|---|"]
    for t in rows:
        if t["dominant"] == "skipped":
            lines.append(f"| {t['arch']} | {t['shape']} | — | — | — | "
                         f"skip: {t['note'][:60]} | — | — |")
            continue
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']*1e3:.2f} | "
            f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
            f"{t['dominant']} | {t['fraction']:.2%} | "
            f"{t['model_hlo_ratio']:.2f} |")
    return "\n".join(lines)


def run() -> List[str]:
    rows = table("single")
    ok = [t for t in rows if t["dominant"] != "skipped"]
    lines = []
    for t in ok:
        lines.append(
            f"roofline_{t['arch']}_{t['shape']},{t['bound_s']*1e6:.1f},"
            f"dominant={t['dominant']};fraction={t['fraction']:.3f};"
            f"model_hlo_ratio={t['model_hlo_ratio']:.2f}")
    if ok:
        worst = min(ok, key=lambda t: t["fraction"])
        coll = max(ok, key=lambda t: t["collective_s"] / t["bound_s"])
        lines.append(
            f"roofline_summary,0.0,cells={len(ok)};"
            f"worst_fraction={worst['arch']}×{worst['shape']}"
            f"({worst['fraction']:.2%});most_collective_bound="
            f"{coll['arch']}×{coll['shape']}")
    return lines


if __name__ == "__main__":
    rows = table("single")
    print(markdown(rows))
    ok = [t for t in rows if t["dominant"] != "skipped"]
    for kind in ("compute", "memory", "collective"):
        n = sum(1 for t in ok if t["dominant"] == kind)
        print(f"# dominant={kind}: {n} cells — fix: {FIX_HINTS[kind]}")
