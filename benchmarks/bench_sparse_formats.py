"""Paper Figs. 13-16: sparse tensor — COO/CSR/CSF/BSGS vs the PT baseline.

Scenario 2 (§V.B): Uber-pickups-like 4-D sparse tensor (0.038% nnz).
Baseline "PT" = the torch.save analog: one blob holding raw COO arrays
(int64 indices + values + shape), which is what a .pt of a
sparse_coo_tensor contains. Each proposed format stores through the delta
table. Metrics per format: storage size (Fig. 13), write time (Fig. 14),
read-tensor time (Fig. 15), read-slice X[i] time (Fig. 16).
"""

from __future__ import annotations

import io
import struct

import numpy as np

from repro.configs.paper_store import PAPER_STORE
from repro.core import DeltaTensorStore
from repro.core.encodings.base import SparseCOO
from repro.data.synthetic import uber_like

from .common import fresh_store, row, timed

FORMATS = ("coo", "csr", "csf", "bsgs")


def _pt_blob(t: SparseCOO) -> bytes:
    """torch .pt analog: header + raw int64 indices + values."""
    buf = io.BytesIO()
    header = struct.pack("<4sIIQ", b"PTAN", t.ndim, t.values.dtype.itemsize,
                         t.nnz)
    buf.write(header)
    buf.write(np.asarray(t.shape, np.int64).tobytes())
    buf.write(t.indices.astype(np.int64).tobytes())
    buf.write(t.values.tobytes())
    return buf.getvalue()


def _pt_parse(raw: bytes, dtype) -> SparseCOO:
    magic, ndim, isz, nnz = struct.unpack_from("<4sIIQ", raw, 0)
    off = struct.calcsize("<4sIIQ")
    shape = tuple(np.frombuffer(raw, np.int64, ndim, off))
    off += 8 * ndim
    idx = np.frombuffer(raw, np.int64, nnz * ndim, off).reshape(nnz, ndim)
    off += 8 * nnz * ndim
    vals = np.frombuffer(raw, dtype, nnz, off)
    return SparseCOO(idx.copy(), vals.copy(), shape)


def run(shape=None, repeats=None):
    cfgs = PAPER_STORE["sparse"]
    t = uber_like(shape or cfgs["bench_shape"], cfgs["nnz_ratio"])
    d0 = t.shape[0]
    sl = (d0 // 2, d0 // 2 + 1)   # X[i] slice, paper's Fig. 16 read
    repeats = repeats or PAPER_STORE["repeats"]
    lines = []

    # --- PT baseline ----------------------------------------------------------
    obj, lm = fresh_store()
    w = timed(lm, lambda: obj.put("pt/x.pt", _pt_blob(t)), repeats)
    size_pt = obj.head("pt/x.pt")
    r = timed(lm, lambda: _pt_parse(obj.get("pt/x.pt"), t.values.dtype).to_dense(),
              repeats)

    def pt_slice():
        full = _pt_parse(obj.get("pt/x.pt"), t.values.dtype)
        full.slice(tuple([sl] + [(0, s) for s in t.shape[1:]])).to_dense()

    s = timed(lm, pt_slice, repeats)
    lines.append(row("sparse_pt_write", w.total_s * 1e6, f"size_bytes={size_pt}"))
    lines.append(row("sparse_pt_read_tensor", r.total_s * 1e6, ""))
    lines.append(row("sparse_pt_read_slice", s.total_s * 1e6,
                     f"bytes_moved={s.bytes_moved}"))

    results = {"pt": (size_pt, w, r, s)}

    # --- proposed formats --------------------------------------------------
    for layout in FORMATS:
        obj, lm = fresh_store()
        store = DeltaTensorStore(obj, "tensors")
        kw = {}
        if layout == "bsgs":
            kw["block_shape"] = cfgs["bsgs_block"]
        if layout == "csr":
            kw["split"] = cfgs["csr_split"]
        w = timed(lm, lambda: store.put(t, layout=layout, tensor_id="x",
                                        overwrite=True, **kw), repeats)
        size = store.tensor_bytes("x")
        r = timed(lm, lambda: store.get("x"), repeats)
        s = timed(lm, lambda: store.get_slice("x", [sl]), repeats)
        results[layout] = (size, w, r, s)
        cr = size / size_pt
        lines.append(row(f"sparse_{layout}_write", w.total_s * 1e6,
                         f"size_bytes={size};Cr_vs_pt={cr:.4f}"))
        lines.append(row(f"sparse_{layout}_read_tensor", r.total_s * 1e6,
                         f"io_s={r.io_s:.3f}"))
        lines.append(row(f"sparse_{layout}_read_slice", s.total_s * 1e6,
                         f"bytes_moved={s.bytes_moved}"))

    # --- paper-claim summary ---------------------------------------------------
    best_cr = min(FORMATS, key=lambda f: results[f][0])
    best_w = min(FORMATS, key=lambda f: results[f][1].total_s)
    best_r = min(FORMATS, key=lambda f: results[f][2].total_s)
    best_s = min(FORMATS, key=lambda f: results[f][3].total_s)
    lines.append(row(
        "sparse_summary", 0.0,
        f"best_Cr={best_cr}({results[best_cr][0]/size_pt:.4f}) "
        f"[paper: bsgs 0.0483]; fastest_write={best_w} [paper: csf]; "
        f"fastest_read={best_r} [paper: bsgs]; fastest_slice={best_s} "
        f"[paper: bsgs]"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
