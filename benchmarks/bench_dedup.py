"""Content-addressed dedup + model-variant delta storage: space accounting.

The fine-tuned-model claim of the CAS subsystem, measured on the paper's
modeled object store (1 Gbps, 10 ms RTT, virtual clock):

* **variant fan-out** — one base model plus ``VARIANTS`` fine-tunes, each
  perturbing a ~5% contiguous slab of the weights. Stored naively
  (``dedup=False``, plain ``put`` per variant) every variant re-uploads
  the full model; stored through ``put_variant`` the unchanged chunks
  dedup into references and the changed chunks XOR-delta against the
  base's objects. The acceptance floor: 8 variants cost <= 2.5x the
  base's physical bytes (vs 9x naive), and every variant reads back
  byte-identical both ways.

* **churn reclamation is exact** — deleting half the variants and
  vacuuming reclaims exactly the objects referenced ONLY by the deleted
  variants: every surviving tensor's objects (including shared dedup'd
  chunks and delta bases) stay put, byte-for-byte.

* **lease safety under churn** — refs opened before the delete+vacuum
  keep reading identical bytes throughout.

With ``--json`` (or :func:`run`'s ``json_path``) results land in
``BENCH_dedup.json`` so ``check_regression.py`` can gate PRs.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core import DeltaTensorStore
from repro.lake import ReadExecutor
from repro.lake.table import physical_path

from .common import fresh_store, row

SHAPE = (32, 64, 64)           # 512 KiB of f32 weights per model
VARIANTS = 8
DELETE_VARIANTS = 4
TARGET_FILE_BYTES = 64 << 10   # many chunk files -> per-chunk dedup matters
SLAB = 2                       # leading-axis rows each variant perturbs (~6%)

MAX_VARIANTS_VS_BASE = 2.5     # acceptance: 8 variants <= 2.5x base physical


def _weights(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(SHAPE)
    return (np.round(x * 64) / 64).astype(np.float32)


def _variant(base, i):
    v = base.copy()
    lo = (i * SLAB) % (SHAPE[0] - SLAB)
    v[lo:lo + SLAB] += 1.0 / (i + 2)
    return v


def _data_bytes(obj, root):
    return sum(obj.head(k) for k in obj.list(f"{root}/")
               if "_delta_log" not in k and "/_catalog/" not in k
               and "/_cas/" not in k and "_store_manifest" not in k)


def _object_keys(store):
    """tensor id -> set of object keys its latest add-actions reference."""
    refs = {}
    cat = store.catalog()
    for tid in cat:
        entry = cat.entry(tid)
        keys = set()
        for a in entry.header_adds + entry.chunk_adds:
            keys.add(f"{store.tables[entry.shard].path}/{physical_path(a)}")
            if a.get("deltaBase"):
                keys.add(a["deltaBase"])
        refs[tid] = keys
    return refs


def _store(obj, root, dedup=True):
    io = ReadExecutor(max_workers=8, cache_bytes=0)
    return DeltaTensorStore(obj, root, io=io, compression="zlib+shuffle",
                            dedup=dedup)


def variant_fanout():
    base = _weights(0)
    variants = [_variant(base, i) for i in range(VARIANTS)]

    # naive: every variant is an independent full put
    obj_n, lm_n = fresh_store(parallelism=8)
    naive = _store(obj_n, "naive", dedup=False)
    naive.put(base, tensor_id="m", layout="ftsf",
              target_file_bytes=TARGET_FILE_BYTES)
    naive_base = _data_bytes(obj_n, "naive")
    lm_n.reset()
    for i, v in enumerate(variants):
        naive.put(v, tensor_id=f"m-ft{i}", layout="ftsf",
                  target_file_bytes=TARGET_FILE_BYTES)
    naive_total = _data_bytes(obj_n, "naive")
    naive_upload = lm_n.bytes_moved   # pure uploads

    # CAS: variants delta-encode against the base's objects
    obj_d, lm_d = fresh_store(parallelism=8)
    store = _store(obj_d, "cas")
    store.put(base, tensor_id="m", layout="ftsf",
              target_file_bytes=TARGET_FILE_BYTES)
    base_phys = _data_bytes(obj_d, "cas")
    lm_d.reset()
    for i, v in enumerate(variants):
        store.put_variant(v, base_tid="m", tensor_id=f"m-ft{i}",
                          target_file_bytes=TARGET_FILE_BYTES)
    total_phys = _data_bytes(obj_d, "cas")
    dedup_upload = lm_d.bytes_moved   # delta uploads + base-blob reads

    for i, v in enumerate(variants):  # both stores read back exactly
        assert np.array_equal(store.get(f"m-ft{i}"), v)
        assert np.array_equal(naive.get(f"m-ft{i}"), v)

    stats = store.storage_stats()
    return store, obj_d, variants, base, {
        "variants": VARIANTS,
        "naive_base_bytes": naive_base,
        "naive_total_bytes": naive_total,
        "base_physical_bytes": base_phys,
        "total_physical_bytes": total_phys,
        "variants_vs_base_ratio": (total_phys - base_phys) / base_phys,
        "naive_vs_dedup": naive_total / total_phys,
        "wire_bytes_naive": naive_upload,
        "wire_bytes_dedup": dedup_upload,
        "dedup": stats["dedup"],
        "logical_bytes": stats["logical_bytes"],
        "physical_bytes": stats["physical_bytes"],
    }


def churn(store, obj, variants, base):
    # steady state first so the churn delta is attributable to the delete
    store.vacuum(keep_versions=1)
    refs_before = _object_keys(store)
    doomed_tids = [f"m-ft{i}" for i in range(DELETE_VARIANTS)]
    survivors = {t: k for t, k in refs_before.items() if t not in doomed_tids}
    survivor_keys = set().union(*survivors.values())
    expected_reclaim = set().union(
        *(refs_before[t] for t in doomed_tids)) - survivor_keys

    # leased refs opened BEFORE the churn must read identically after it
    leased = [store.open("m"), store.open(f"m-ft{VARIANTS - 1}")]

    for t in doomed_tids:
        store.delete(t)
    # pass 1 runs under the leases: they pin the pre-delete snapshot, so
    # the doomed variants' objects are NOT reclaimable yet (lease safety)
    pass1 = store.vacuum(keep_versions=1)
    leased_ok = (np.array_equal(leased[0].read(), base) and
                 np.array_equal(leased[1].read(), variants[VARIANTS - 1]))
    for ref in leased:
        ref.close()
    # pass 2 after release: now exactly the doomed-only objects go
    pass2 = store.vacuum(keep_versions=1)
    results = pass1 + pass2
    deleted = {f"{store.tables[s % store.shards].path}/{p}"
               for s, r in enumerate(results) for p in r.deleted_paths}

    reclaim_exact = deleted == expected_reclaim

    # survivors still read exactly after lease release + final vacuum
    survivors_ok = np.array_equal(store.get("m"), base) and all(
        np.array_equal(store.get(f"m-ft{i}"), variants[i])
        for i in range(DELETE_VARIANTS, VARIANTS))

    return {
        "deleted_variants": DELETE_VARIANTS,
        "files_reclaimed": sum(r.files_deleted for r in results),
        "files_reclaimed_while_leased": sum(r.files_deleted for r in pass1),
        "bytes_reclaimed": sum(r.bytes_reclaimed for r in results),
        "expected_objects": len(expected_reclaim),
        "reclaimed_objects": len(deleted),
        "reclaim_exact": reclaim_exact,
        "leased_identical": leased_ok,
        "survivors_identical": survivors_ok,
    }


def run(json_path=None):
    results = {"bench": "dedup"}
    lines = []

    store, obj, variants, base, fan = variant_fanout()
    ch = churn(store, obj, variants, base)
    results["fanout"] = fan
    results["churn"] = ch
    results["gate"] = {
        "variants_vs_base_ratio": fan["variants_vs_base_ratio"],
        "naive_vs_dedup": fan["naive_vs_dedup"],
        "reclaim_exact": ch["reclaim_exact"],
        "leased_identical": ch["leased_identical"],
        "survivors_identical": ch["survivors_identical"],
    }

    lines.append(row(
        "dedup_variant_fanout", 0.0,
        f"{VARIANTS} variants add "
        f"{fan['variants_vs_base_ratio']:.2f}x base physical "
        f"(naive {fan['naive_vs_dedup']:.2f}x larger) "
        f"wire {fan['wire_bytes_dedup']}B vs {fan['wire_bytes_naive']}B"))
    lines.append(row(
        "dedup_churn_reclaim", 0.0,
        f"deleted {DELETE_VARIANTS} variants -> "
        f"{ch['reclaimed_objects']}/{ch['expected_objects']} objects "
        f"exact={ch['reclaim_exact']} leased_ok={ch['leased_identical']}"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_dedup.json"):
        print(line)
