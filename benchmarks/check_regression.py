"""Bench-regression gate: fresh BENCH_*.json vs the committed baselines.

CI's ``bench-smoke`` job re-runs the virtual-clock benchmarks and calls

    python benchmarks/check_regression.py --fresh bench_out --baseline .

which fails (exit 1) when a gated metric regresses more than ``--tolerance``
(default 20%) below its committed baseline, or when any shard-scale
configuration lost a write. Gated metrics:

* ``BENCH_read_path.json``  — width-8 parallel ``get`` speedup over serial,
  plus the device-pipeline invariants: the cold compressed read-to-device
  makespan stays <= 0.8x of un-pipelined fetch-then-decode, a positive
  fraction of decode seconds hides under the wire, and the device slice
  read stages only the wanted chunk bytes (no full-tensor host copy);
* ``BENCH_shard_scale.json`` — 4-shard commit-throughput ratio vs 1 shard
  under 8 concurrent writers (the sharding scale-out claim), plus the
  zero-lost-writes invariant across every writer/shard configuration;
* ``BENCH_maintenance.json`` — fraction of data bytes vacuum reclaims
  after the churn workload (also hard-floored at 0.50 regardless of
  baseline), modeled-I/O speedup of a spilled-index catalog build over a
  snapshot walk, and the invariant that the spilled build performed zero
  snapshot walks;
* ``BENCH_compression.json`` — physical-byte reduction of the
  ``zlib+shuffle`` chunk-blob codec on the compressible dense-float
  workload (also hard-floored at 2.0x vs raw tensor bytes), and the
  invariant that the compressed store's full-read makespan stays within
  25% of the uncompressed store's;
* ``BENCH_stream_loader.json`` — width-8 sustained streaming-loader
  throughput vs serial awaited gets (also hard-floored at 2.0x), plus
  the invariants that the per-batch p99 latency is reported non-null and
  peak prefetch memory stayed within the ``window x batch_bytes`` bound;
* ``BENCH_dedup.json`` — naive-vs-CAS physical-byte ratio for the
  8-variant fine-tune fan-out, plus the invariants that the variants add
  at most 2.5x the base's physical bytes, that deleting half the
  variants + vacuum reclaims EXACTLY their unshared objects, and that
  leased reads stayed byte-identical through the churn;
* ``BENCH_ingest.json`` — watermark-64 streaming-ingest throughput vs the
  eager batch-put baseline (also hard-floored at 1.0x: streaming must not
  be a throughput tax), the live-reader invariant that an epoch streamed
  while a writer commits stays within 1.2x of the quiesced epoch, and the
  crash invariants that a writer killed at every flush seam tears ZERO
  visible versions with vacuum reclaiming EXACTLY the orphans;
* ``BENCH_serve_traffic.json`` — gateway cold-start coalescing: store
  requests issued by N independent frontends vs the single-flighted
  gateway (also hard-floored at 2.0x, with >= 1 coalesced flight join
  and byte-identical trees for every waiter), the invariant that a warm
  re-read of the pinned hot-base partition issues ZERO store requests
  while long-tail churn evicts, the mid-run Jain fairness index across
  burst-submitting tenants (hard floor 0.80), a non-null per-tenant p99,
  and at least one shed request from the flooded bounded queue.

Improvements never fail the gate; commit a refreshed baseline JSON when a
PR deliberately moves a metric.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_TOLERANCE = 0.20

GATES = [
    ("BENCH_read_path.json", "width-8 get speedup",
     lambda d: float(d["speedup"]["8"]["get"])),
    ("BENCH_shard_scale.json", "4-shard/1-shard commit throughput @ 8 writers",
     lambda d: float(d["throughput_ratio_vs_1shard_w8"]["4"])),
    ("BENCH_maintenance.json", "vacuum reclaimed fraction after churn",
     lambda d: float(d["churn"]["reclaimed_frac"])),
    ("BENCH_maintenance.json", "spilled-index catalog build io speedup",
     lambda d: float(d["catalog"]["speedup_io"])),
    ("BENCH_compression.json", "zlib+shuffle physical reduction",
     lambda d: float(d["gate"]["reduction"])),
    ("BENCH_stream_loader.json", "width-8 loader vs serial-gets throughput",
     lambda d: float(d["gate"]["loader_vs_serial_w8"])),
    ("BENCH_dedup.json", "naive vs CAS physical bytes (8-variant fan-out)",
     lambda d: float(d["gate"]["naive_vs_dedup"])),
    ("BENCH_ingest.json", "watermark-64 ingest vs batch-put throughput",
     lambda d: float(d["gate"]["ingest_vs_batch_put"])),
    ("BENCH_serve_traffic.json", "gateway cold-start coalescing request ratio",
     lambda d: float(d["gate"]["coalesce_requests_ratio"])),
    ("BENCH_serve_traffic.json", "mid-run Jain fairness under burst traffic",
     lambda d: float(d["gate"]["jain_mid_run"])),
]

# invariants checked on the fresh run only (no baseline comparison)
MIN_RECLAIMED_FRAC = 0.50
MIN_COMPRESSION_REDUCTION = 2.0       # vs raw tensor bytes (acceptance)
MAX_COMPRESSED_READ_OVERHEAD = 1.25   # full-read makespan vs uncompressed
MIN_LOADER_VS_SERIAL_W8 = 2.0         # streaming loader throughput (acceptance)
MAX_VARIANTS_VS_BASE = 2.5            # 8 variants' physical bytes vs base
MIN_COALESCE_RATIO = 2.0              # uncoalesced/coalesced store requests
MIN_SERVE_FAIRNESS = 0.80             # mid-run Jain index (acceptance)
MAX_DEVICE_PIPELINE_RATIO = 0.8       # pipelined / fetch-then-decode (accept.)
MIN_INGEST_VS_BATCH_PUT = 1.0         # streaming ingest parity (acceptance)
MAX_LIVE_READER_OVERHEAD = 1.2        # live epoch / quiesced epoch (accept.)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="bench_out",
                    help="dir holding freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default=".",
                    help="dir holding the committed baseline BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args(argv)

    failures = []
    for fname, label, metric in GATES:
        fresh = _load(os.path.join(args.fresh, fname))
        base = _load(os.path.join(args.baseline, fname))
        got, want = metric(fresh), metric(base)
        floor = want * (1.0 - args.tolerance)
        verdict = "OK" if got >= floor else "REGRESSION"
        print(f"[{verdict}] {label}: fresh={got:.3f} baseline={want:.3f} "
              f"floor={floor:.3f}")
        if got < floor:
            failures.append(label)

    rp = _load(os.path.join(args.fresh, "BENCH_read_path.json"))
    dev = rp["device"]
    dratio = float(dev["pipelined_vs_serial"])
    doverlap = float(dev["decode_overlap_frac"])
    dzero = bool(dev["slice"]["zero_full_tensor_host_copies"])
    if dratio > MAX_DEVICE_PIPELINE_RATIO:
        print(f"[REGRESSION] device pipelined read is {dratio:.2f}x "
              f"fetch-then-decode > ceiling {MAX_DEVICE_PIPELINE_RATIO:.2f}x; "
              f"decode no longer overlaps fetch")
        failures.append("device pipeline ratio ceiling")
    if doverlap <= 0.0:
        print(f"[REGRESSION] decode_overlap_frac={doverlap:.3f}; no decode "
              f"seconds hid under the wire")
        failures.append("device decode overlap")
    if not dzero:
        print(f"[REGRESSION] device slice staged "
              f"{dev['slice']['host_staged_bytes']} host bytes for a "
              f"{dev['slice']['device_bytes']}-byte window "
              f"(full tensor {dev['slice']['full_tensor_bytes']}); the "
              f"zero-full-tensor-host-copy invariant broke")
        failures.append("device slice zero-copy")
    if dratio <= MAX_DEVICE_PIPELINE_RATIO and doverlap > 0.0 and dzero:
        print(f"[OK] device pipeline: {dratio:.2f}x fetch-then-decode "
              f"({doverlap:.0%} of decode hidden), slice staged only the "
              f"wanted {dev['slice']['host_staged_bytes']} bytes")

    shard = _load(os.path.join(args.fresh, "BENCH_shard_scale.json"))
    for writers, per_shards in sorted(shard["writers"].items()):
        for shards, r in sorted(per_shards.items()):
            lost = int(r.get("lost_writes", 0))
            if lost:
                print(f"[REGRESSION] lost writes: {lost} "
                      f"(shards={shards}, writers={writers})")
                failures.append(f"lost_writes s{shards} w{writers}")
    if not failures:
        print("[OK] zero lost writes in every shard/writer configuration")

    maint = _load(os.path.join(args.fresh, "BENCH_maintenance.json"))
    frac = float(maint["churn"]["reclaimed_frac"])
    if frac < MIN_RECLAIMED_FRAC:
        print(f"[REGRESSION] churn vacuum reclaimed {frac:.2f} "
              f"< hard floor {MIN_RECLAIMED_FRAC:.2f}")
        failures.append("churn reclaimed_frac floor")
    walks = int(maint["catalog"]["spilled"]["snapshot_walks"])
    if walks != 0:
        print(f"[REGRESSION] spilled catalog build did {walks} snapshot "
              f"walk(s); must be 0")
        failures.append("spilled catalog snapshot_walks")
    if frac >= MIN_RECLAIMED_FRAC and walks == 0:
        print(f"[OK] churn reclaim {frac:.2f} >= {MIN_RECLAIMED_FRAC:.2f}; "
              f"spilled catalog build walked 0 snapshots")

    comp = _load(os.path.join(args.fresh, "BENCH_compression.json"))
    reduction = float(comp["gate"]["reduction"])
    overhead = float(comp["gate"]["read_makespan_ratio"])
    if reduction < MIN_COMPRESSION_REDUCTION:
        print(f"[REGRESSION] compression reduction {reduction:.2f}x "
              f"< hard floor {MIN_COMPRESSION_REDUCTION:.2f}x")
        failures.append("compression reduction floor")
    if overhead > MAX_COMPRESSED_READ_OVERHEAD:
        print(f"[REGRESSION] compressed full-read makespan {overhead:.2f}x "
              f"uncompressed > ceiling {MAX_COMPRESSED_READ_OVERHEAD:.2f}x")
        failures.append("compressed read overhead ceiling")
    if reduction >= MIN_COMPRESSION_REDUCTION and \
            overhead <= MAX_COMPRESSED_READ_OVERHEAD:
        print(f"[OK] compression: {reduction:.2f}x reduction at "
              f"{overhead:.2f}x read makespan")

    loader = _load(os.path.join(args.fresh, "BENCH_stream_loader.json"))
    lgate = loader["gate"]
    lratio = float(lgate["loader_vs_serial_w8"])
    if lratio < MIN_LOADER_VS_SERIAL_W8:
        print(f"[REGRESSION] w8 loader throughput {lratio:.2f}x serial "
              f"< hard floor {MIN_LOADER_VS_SERIAL_W8:.2f}x")
        failures.append("stream loader throughput floor")
    if lgate.get("batch_p99_s") is None:
        print("[REGRESSION] stream loader batch p99 latency is null; "
              "latency histogram must report")
        failures.append("stream loader p99 missing")
    if not lgate.get("memory_bounded"):
        print(f"[REGRESSION] stream loader prefetch exceeded its memory "
              f"bound: peak={lgate.get('peak_inflight_bytes')} "
              f"> bound={lgate.get('memory_bound_bytes')}")
        failures.append("stream loader memory bound")
    if lratio >= MIN_LOADER_VS_SERIAL_W8 and \
            lgate.get("batch_p99_s") is not None and lgate.get("memory_bounded"):
        print(f"[OK] stream loader: {lratio:.2f}x serial at w8, "
              f"batch p99 {float(lgate['batch_p99_s']):.4f}s, "
              f"prefetch memory within bound")

    dedup = _load(os.path.join(args.fresh, "BENCH_dedup.json"))
    dgate = dedup["gate"]
    vratio = float(dgate["variants_vs_base_ratio"])
    if vratio > MAX_VARIANTS_VS_BASE:
        print(f"[REGRESSION] {dedup['fanout']['variants']} variants cost "
              f"{vratio:.2f}x base physical bytes > ceiling "
              f"{MAX_VARIANTS_VS_BASE:.2f}x")
        failures.append("variant fan-out physical ceiling")
    if not dgate.get("reclaim_exact"):
        print(f"[REGRESSION] variant churn reclaim not exact: "
              f"{dedup['churn']['reclaimed_objects']} reclaimed vs "
              f"{dedup['churn']['expected_objects']} doomed-only objects")
        failures.append("dedup reclaim exactness")
    if not (dgate.get("leased_identical") and dgate.get("survivors_identical")):
        print("[REGRESSION] reads diverged during variant churn "
              f"(leased={dgate.get('leased_identical')} "
              f"survivors={dgate.get('survivors_identical')})")
        failures.append("dedup churn read identity")
    if vratio <= MAX_VARIANTS_VS_BASE and dgate.get("reclaim_exact") and \
            dgate.get("leased_identical") and dgate.get("survivors_identical"):
        print(f"[OK] dedup: variants at {vratio:.2f}x base physical "
              f"(naive {float(dgate['naive_vs_dedup']):.2f}x larger), "
              f"churn reclaim exact, leased reads identical")

    ingest = _load(os.path.join(args.fresh, "BENCH_ingest.json"))
    igate = ingest["gate"]
    iratio = float(igate["ingest_vs_batch_put"])
    ioverhead = float(igate["live_reader_overhead"])
    itorn = int(igate["torn_versions"])
    if iratio < MIN_INGEST_VS_BATCH_PUT:
        print(f"[REGRESSION] watermark ingest at {iratio:.2f}x batch-put "
              f"< hard floor {MIN_INGEST_VS_BATCH_PUT:.2f}x; streaming "
              f"became a throughput tax")
        failures.append("ingest parity floor")
    if ioverhead > MAX_LIVE_READER_OVERHEAD:
        print(f"[REGRESSION] live-reader epoch at {ioverhead:.2f}x quiesced "
              f"> ceiling {MAX_LIVE_READER_OVERHEAD:.2f}x; ingest commits "
              f"are blocking readers")
        failures.append("ingest live-reader ceiling")
    if itorn != 0:
        print(f"[REGRESSION] {itorn} torn visible version(s) after "
              f"crash-at-every-seam; commits must be all-or-nothing")
        failures.append("ingest torn versions")
    if not igate.get("orphan_reclaim_exact"):
        print("[REGRESSION] vacuum after a crashed flush did not reclaim "
              "exactly the crash's orphans")
        failures.append("ingest orphan reclaim")
    if iratio >= MIN_INGEST_VS_BATCH_PUT and \
            ioverhead <= MAX_LIVE_READER_OVERHEAD and itorn == 0 and \
            igate.get("orphan_reclaim_exact"):
        print(f"[OK] ingest: {iratio:.2f}x batch-put, live reader at "
              f"{ioverhead:.2f}x quiesced, {len(ingest['crash']['seams'])} "
              f"crash seams torn-free with exact reclaim")

    serve = _load(os.path.join(args.fresh, "BENCH_serve_traffic.json"))
    sgate = serve["gate"]
    sratio = float(sgate["coalesce_requests_ratio"])
    if sratio < MIN_COALESCE_RATIO:
        print(f"[REGRESSION] gateway coalescing saved only {sratio:.2f}x "
              f"store requests < hard floor {MIN_COALESCE_RATIO:.2f}x")
        failures.append("gateway coalesce ratio floor")
    if int(sgate.get("coalesced_dedups", 0)) < 1:
        print("[REGRESSION] no cold-start load joined an existing flight; "
              "single-flight coalescing is dead")
        failures.append("gateway coalesced_dedups")
    if not sgate.get("trees_identical"):
        print("[REGRESSION] coalesced waiters received non-identical "
              "weight trees")
        failures.append("gateway coalesced tree identity")
    if int(sgate.get("warm_base_requests", -1)) != 0:
        print(f"[REGRESSION] warm hot-base re-read issued "
              f"{sgate.get('warm_base_requests')} store request(s); the "
              f"pinned partition must serve it with 0")
        failures.append("gateway warm-base requests")
    sjain = float(sgate["jain_mid_run"])
    if sjain < MIN_SERVE_FAIRNESS:
        print(f"[REGRESSION] mid-run Jain fairness {sjain:.3f} "
              f"< hard floor {MIN_SERVE_FAIRNESS:.2f}")
        failures.append("gateway fairness floor")
    if sgate.get("p99_max_s") is None:
        print("[REGRESSION] per-tenant p99 is null; SLO histograms "
              "must report")
        failures.append("gateway p99 missing")
    if int(sgate.get("shed_rejected", 0)) < 1:
        print("[REGRESSION] flooded bounded queue shed nothing; "
              "overload protection is dead")
        failures.append("gateway shedding")
    if not [f for f in failures if f.startswith("gateway")]:
        print(f"[OK] gateway: coalescing saved {sratio:.2f}x requests, "
              f"warm hot-base at 0 store requests, Jain {sjain:.3f}, "
              f"p99 {float(sgate['p99_max_s']):.4f}s, "
              f"{int(sgate['shed_rejected'])} shed")

    if failures:
        print(f"FAIL: {len(failures)} gate(s) regressed: "
              + "; ".join(failures), file=sys.stderr)
        return 1
    print("PASS: all bench gates within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
