"""Shared benchmark machinery: paper-style metrics over the modeled
object store (1 Gbps + 10 ms RTT, the paper's testbed network), with
modeled I/O time and real encode/decode CPU time reported separately and
summed — reproducing Eqs. (7)-(10)."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

from repro.configs.paper_store import PAPER_STORE
from repro.lake import InMemoryObjectStore, LatencyModel


@dataclass
class OpCost:
    cpu_s: float
    io_s: float
    bytes_moved: int

    @property
    def total_s(self) -> float:
        return self.cpu_s + self.io_s


def fresh_store(parallelism: int = 1):
    """Modeled object store; ``parallelism`` = concurrent channel width
    (the LatencyModel reports makespan instead of serial sum when > 1)."""
    lm = LatencyModel(rtt_s=PAPER_STORE["object_store"]["rtt_s"],
                      bandwidth_bps=PAPER_STORE["object_store"]["bandwidth_bps"],
                      virtual_clock=True, parallelism=parallelism,
                      occupancy_scale=0.05 if parallelism > 1 else 0.0)
    return InMemoryObjectStore(latency=lm), lm


def timed(lm: LatencyModel, fn: Callable, repeats: int = 1) -> OpCost:
    best = None
    for _ in range(repeats):
        lm.reset()
        t0 = time.perf_counter()
        fn()
        cpu = time.perf_counter() - t0
        # io_elapsed_s is the pure-wire makespan: decode seconds are
        # already inside the wall-clock cpu term, and the staged read
        # path also charges them into elapsed_s (the pipelined makespan),
        # so summing cpu + elapsed_s would count decode twice
        cost = OpCost(cpu_s=cpu, io_s=lm.io_elapsed_s,
                      bytes_moved=lm.bytes_moved)
        if best is None or cost.total_s < best.total_s:
            best = cost
    return best


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
