"""Kernel microbenchmarks: the paper's encode/decode hot loops (Eq. 8).

On this CPU box the *compiled* path is the jnp reference (Pallas interpret
mode is a correctness tool, not a perf path), so timings compare the
vectorized encode/decode against a naive per-element baseline and report
achieved effective bandwidth — the TPU kernels are validated separately in
tests/test_kernels.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device as dev
from repro.kernels import ref

from .common import row


def _time(fn, *args, repeats=5):
    fn(*args)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    lines = []
    rng = np.random.default_rng(0)

    # BSGS block gather/scatter (encode/decode hot loop)
    x = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    ids = jnp.asarray(rng.choice(8192, 512, replace=False), jnp.int32)
    bs = (8, 128)
    t = _time(lambda a, i: ref.block_gather(a, i, bs), x, ids)
    moved = 512 * 8 * 128 * 4
    lines.append(row("kernel_block_gather", t * 1e6,
                     f"eff_GBps={moved/t/1e9:.2f}"))
    blocks = ref.block_gather(x, ids, bs)
    t = _time(lambda a, i, b: ref.block_scatter(a, i, b), x, ids, blocks)
    lines.append(row("kernel_block_scatter", t * 1e6,
                     f"eff_GBps={(moved + x.nbytes)/t/1e9:.2f}"))

    # block norms (gradient-compression reduction)
    bv = jnp.asarray(rng.standard_normal((8192, 1024)), jnp.float32)
    t = _time(ref.block_norms, bv)
    lines.append(row("kernel_block_norms", t * 1e6,
                     f"eff_GBps={bv.nbytes/t/1e9:.2f}"))

    # COO scatter (decode) vs dense copy baseline
    size = 1 << 20
    k = 4096
    idx = jnp.asarray(rng.choice(size, k, replace=False), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(k), jnp.float32)
    t = _time(lambda i, v: ref.coo_scatter(i, v, size), idx, vals)
    lines.append(row("kernel_coo_scatter", t * 1e6, f"nnz={k};size={size}"))

    # device codecs end-to-end (fixed-capacity encode+decode roundtrip)
    xs = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    xs = jnp.where(jnp.abs(xs) > 2.0, xs, 0.0)  # ~5% density

    def roundtrip(a):
        c = dev.bsgs_encode(a, (8, 128), 256)
        return dev.bsgs_decode(c, a.shape, (8, 128))

    t = _time(roundtrip, xs)
    lines.append(row("kernel_bsgs_roundtrip", t * 1e6,
                     f"density={float(jnp.mean(xs != 0)):.4f}"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
