"""Serving example: continuous batching over a reduced model.

    PYTHONPATH=src python examples/serve_lm.py --arch glm4-9b --requests 12

Requests with ragged prompt lengths stream through a fixed pool of slots;
a finished sequence's slot is immediately re-admitted from the queue.

With ``--from-store`` the weights round-trip through the Delta Tensor
store first via the ``store.models(prefix)`` handle: saved as one FTSF
tensor per param leaf, then cold-start loaded with every leaf fetched in
parallel on the shared ReadExecutor.
"""

import argparse
import time

import jax
import numpy as np

from repro.models import get_arch, transformer
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--from-store", action="store_true",
                    help="round-trip weights through the Delta Tensor store "
                         "(parallel cold-start weight load)")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} has no decode step")
    params = transformer.init_params(cfg, jax.random.key(0))

    if args.from_store:
        from repro.core import DeltaTensorStore
        from repro.lake import InMemoryObjectStore, ReadExecutor
        store = DeltaTensorStore(InMemoryObjectStore(), "weights",
                                 io=ReadExecutor(max_workers=8))
        with store.models(cfg.name) as repo:
            repo.save(params)
            t0 = time.time()
            params = repo.load(params)
        st = store.io.stats
        print(f"weights loaded from delta store in {time.time() - t0:.2f}s "
              f"(gets={st.gets} cache_hits={st.cache_hits})")

    extra = {}
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.numpy.zeros(
            (args.slots, cfg.n_image_tokens, cfg.d_model), jax.numpy.float32)

    eng = ServeEngine(params, cfg, n_slots=args.slots, max_len=128,
                      extra_inputs=extra)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(4, 24)),)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    iters = 0
    while any(not r.done for r in reqs):
        eng.step()
        iters += 1
        if iters > 10_000:
            raise RuntimeError("stuck")
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"arch={cfg.name} slots={args.slots}")
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, {iters} engine steps)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
