"""Quickstart: the paper's system in 60 lines.

Store tensors in a delta table under all five formats, read them lazily
through snapshot-pinned TensorRef handles, slice-read without touching most
of the data, batch writes atomically, and time-travel.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import DeltaTensorStore, choose_layout
from repro.data.synthetic import uber_like
from repro.lake import InMemoryObjectStore, LatencyModel


def main():
    lm = LatencyModel()                      # modeled 1 Gbps object store
    store = DeltaTensorStore(InMemoryObjectStore(latency=lm), "tensors",
                             compression="zlib+shuffle")  # chunk-blob codec

    # --- dense tensor -> FTSF (the 10% rule picks it automatically) -------
    dense = np.random.default_rng(0).standard_normal((64, 3, 32, 32)).astype(
        np.float32)
    print("policy for dense tensor:", choose_layout(dense))
    store.put(dense, tensor_id="images",                # auto -> ftsf
              target_file_bytes=64 << 10)               # ~12 chunk files

    # --- lazy handle: metadata costs one header read, slicing is numpy ----
    ref = store.open("images")
    print(f"{ref!r}: shape={ref.shape} dtype={ref.dtype} "
          f"stored={ref.nbytes/1e3:.1f} kB in {ref.n_chunk_files} chunk files")

    lm.reset()
    sl = ref[10:14]                                    # 4 of 64 chunks
    print(f"slice read moved {lm.bytes_moved/1e3:.1f} kB "
          f"(full tensor is {dense.nbytes/1e3:.1f} kB)")
    np.testing.assert_array_equal(sl, dense[10:14])
    np.testing.assert_array_equal(ref[0, ..., 16], dense[0, ..., 16])

    fut = ref.read_async()                             # fans out on the executor
    np.testing.assert_array_equal(fut.result(), dense)

    # --- sparse tensor -> every sparse format, one atomic commit ----------
    sparse = uber_like((48, 24, 64, 64), nnz_ratio=0.002)
    print(f"\nsparse tensor: {sparse.shape}, nnz={sparse.nnz} "
          f"({sparse.density:.4%})")
    with store.batch(op="PUT ALL SPARSE FORMATS") as b:
        for layout in ("coo", "csr", "csc", "csf", "bsgs"):
            b.put(sparse, layout=layout, tensor_id=f"pickups-{layout}")
    for layout in ("coo", "csr", "csc", "csf", "bsgs"):
        r = store.open(f"pickups-{layout}")
        print(f"  {layout:5s}: {r.nbytes/1e3:8.1f} kB "
              f"({r.nbytes/(sparse.nnz*40):.2%} of a COO blob) "
              f"coo-native={r.codec.supports_coo}")
        np.testing.assert_array_equal(r.read(), sparse.to_dense())

    # slice read: day 7 only, via block/fiber pushdown
    np.testing.assert_array_equal(store.open("pickups-bsgs")[7:8],
                                  sparse.to_dense()[7:8])

    # --- ACID + time travel -------------------------------------------------
    v = store.version()
    old = store.open("images")                         # pinned at v
    store.put(dense * 2, tensor_id="images", overwrite=True,
              target_file_bytes=64 << 10)   # same chunk-file grid as v1
    np.testing.assert_array_equal(store.open("images").read(), dense * 2)
    np.testing.assert_array_equal(old.read(), dense)   # ref still sees v
    np.testing.assert_array_equal(store.open("images", version=v).read(), dense)
    print(f"\ntime travel: a ref pinned at v{v} still serves the original")
    print("tensors in store:", [t for t, _ in store.list_tensors()])
    print("catalog metadata work:", store.catalog_stats)

    # --- model variants: dedup + delta-encode against a base tensor -------
    # a "fine-tune" that only nudges a slab of the weights: unchanged
    # chunks commit as references to the base's objects (no upload) and
    # changed chunks store as XOR deltas -- reads stay transparent
    variant = (dense * 2).copy()        # current contents of "images"
    variant[:8] *= 1.01                 # ...with 1/8 of the rows nudged
    store.put_variant(variant, base_tid="images", tensor_id="images-ft",
                      target_file_bytes=64 << 10)
    np.testing.assert_array_equal(store.open("images-ft").read(), variant)

    # --- space accounting: logical vs physical bytes, dedup, per codec ----
    st = store.storage_stats()
    print(f"\nstorage: {st['physical_bytes']/1e3:.1f} kB physical / "
          f"{st['logical_bytes']/1e3:.1f} kB logical "
          f"({st['ratio']:.2f}x, default codec {st['compression']!r})")
    d = st["dedup"]
    print(f"dedup: {d['deduped_refs']} of {d['references']} chunk refs "
          f"reused an object ({d['saved_bytes']/1e3:.1f} kB saved), "
          f"{d['delta_files']} variant chunks stored as deltas")


if __name__ == "__main__":
    main()
