"""Quickstart: the paper's system in 60 lines.

Store tensors in a delta table under all five formats, read them back,
slice-read without touching most of the data, and time-travel.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import DeltaTensorStore, SparseCOO, choose_layout
from repro.data.synthetic import uber_like
from repro.lake import InMemoryObjectStore, LatencyModel


def main():
    lm = LatencyModel()                      # modeled 1 Gbps object store
    store = DeltaTensorStore(InMemoryObjectStore(latency=lm), "tensors")

    # --- dense tensor -> FTSF (the 10% rule picks it automatically) -------
    dense = np.random.default_rng(0).standard_normal((64, 3, 32, 32)).astype(
        np.float32)
    print("policy for dense tensor:", choose_layout(dense))
    tid = store.put(dense, tensor_id="images",          # auto -> ftsf
                target_file_bytes=64 << 10)         # ~12 chunk files
    np.testing.assert_array_equal(store.get("images"), dense)

    lm.reset()
    sl = store.get_slice("images", [(10, 14)])         # 4 of 64 chunks
    print(f"slice read moved {lm.bytes_moved/1e3:.1f} kB "
          f"(full tensor is {dense.nbytes/1e3:.1f} kB)")
    np.testing.assert_array_equal(sl, dense[10:14])

    # --- sparse tensor -> every sparse format ------------------------------
    sparse = uber_like((48, 24, 64, 64), nnz_ratio=0.002)
    print(f"\nsparse tensor: {sparse.shape}, nnz={sparse.nnz} "
          f"({sparse.density:.4%})")
    for layout in ("coo", "csr", "csc", "csf", "bsgs"):
        tid = store.put(sparse, layout=layout, tensor_id=f"pickups-{layout}")
        nbytes = store.tensor_bytes(tid)
        print(f"  {layout:5s}: {nbytes/1e3:8.1f} kB "
              f"({nbytes/(sparse.nnz*40):.2%} of a COO blob)")
        np.testing.assert_array_equal(store.get(tid), sparse.to_dense())

    # slice read: day 7 only, via block/fiber pushdown
    np.testing.assert_array_equal(store.get_slice("pickups-bsgs", [(7, 8)]),
                                  sparse.to_dense()[7:8])

    # --- ACID + time travel -------------------------------------------------
    v = store.version()
    store.put(dense * 2, tensor_id="images", overwrite=True)
    np.testing.assert_array_equal(store.get("images"), dense * 2)
    np.testing.assert_array_equal(store.get("images", version=v), dense)
    print(f"\ntime travel: version {v} still serves the original tensor")
    print("tensors in store:", [t for t, _ in store.list_tensors()])


if __name__ == "__main__":
    main()
