"""End-to-end training driver: FTSF data pipeline -> train -> delta
checkpoints -> crash -> elastic restore -> resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # default tiny model
    PYTHONPATH=src python examples/train_lm.py --arch glm4-9b       # reduced twin of any arch
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300   # ~100M params

Every piece is the production path: the dataset lives as FTSF chunk rows in
a delta table (batch fetch = the paper's slice read), checkpoints are
incremental FTSF tensors committed atomically, and the run demonstrates a
mid-training failure + restore-from-last-commit.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DeltaTensorStore
from repro.data.pipeline import FTSFLoader, write_token_dataset
from repro.data.synthetic import token_stream
from repro.lake import InMemoryObjectStore
from repro.models import get_arch
from repro.models.config import ArchConfig, register_arch
from repro.train import checkpoint as ckpt_mod, optimizer as opt, trainer


def size_100m() -> ArchConfig:
    return register_arch(ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=8192, head_dim=64,
        dtype="float32", attn_chunk_q=128, attn_chunk_kv=128))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--size", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = size_100m() if args.size == "100m" else get_arch(args.arch).reduced()
    if args.size == "100m":
        args.seq = max(args.seq, 128)
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model}")

    # --- dataset as FTSF rows in the delta lake -----------------------------
    obj = InMemoryObjectStore()
    data_store = DeltaTensorStore(obj, "datasets")
    tokens = token_stream(1024, args.seq, cfg.vocab_size)
    write_token_dataset(data_store, tokens, tensor_id="corpus")
    loader = FTSFLoader(data_store, "corpus", batch_size=args.batch, seed=0)

    # --- train state + jit step ---------------------------------------------
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    state = trainer.init_state(cfg, jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"params: {n_params/1e6:.1f}M")
    step_fn = jax.jit(trainer.make_train_step(cfg, ocfg))
    ckpt = ckpt_mod.DeltaCheckpointer(obj, "checkpoints")

    it = iter(loader)
    t0 = time.time()
    crash_at = args.steps // 2
    losses = []
    for i in range(crash_at):
        b = next(it)
        state, m = step_fn(state, {"tokens": jnp.asarray(b["tokens"]),
                                   "labels": jnp.asarray(b["labels"])})
        losses.append(float(m["loss"]))
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save_async(i + 1, state)     # overlaps the next steps
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d} loss {losses[-1]:.3f} "
                  f"({(i+1)/(time.time()-t0):.1f} steps/s)")
    ckpt.wait()

    # --- simulated failure + elastic restore --------------------------------
    print(f"\n-- simulating node failure at step {crash_at} --")
    del state
    last = max(ckpt.steps())
    template = trainer.init_state(cfg, jax.random.key(0))
    step_found, state = ckpt.restore(template)
    print(f"restored checkpoint of step {step_found} "
          f"(lost {crash_at - step_found} steps, by design)")

    loader2 = FTSFLoader(data_store, "corpus", batch_size=args.batch, seed=0,
                         start_step=step_found)
    it = iter(loader2)
    for i in range(step_found, args.steps):
        b = next(it)
        state, m = step_fn(state, {"tokens": jnp.asarray(b["tokens"]),
                                   "labels": jnp.asarray(b["labels"])})
        losses.append(float(m["loss"]))
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d} loss {float(m['loss']):.3f}")
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save_async(i + 1, state)
    ckpt.wait()
    loader.close()
    loader2.close()
    print(f"\nfinal loss {losses[-1]:.3f} (start {losses[0]:.3f}); "
          f"checkpoints at steps {ckpt.steps()}")
    assert losses[-1] < losses[0], "training should reduce loss"


if __name__ == "__main__":
    main()
