"""Cross-pod gradient compression demo — the paper's BSGS on the wire.

    PYTHONPATH=src python examples/grad_compression.py --steps 40

Two simulated pods train in data parallel; each step exchanges only the
top-k energy blocks of the gradients (+ error feedback). The demo compares
loss curves and wire bytes against dense synchronization.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_arch
from repro.train import optimizer as opt, trainer


def run(compressed: bool, steps: int, ratio: float):
    cfg = get_arch("granite-3-8b").reduced()
    ocfg = opt.OptConfig(lr=5e-3, warmup_steps=5, total_steps=steps)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((4, 1), jnp.int32)], 1)
    batch = {"tokens": tokens.reshape(2, 2, 32),
             "labels": labels.reshape(2, 2, 32)}

    if compressed:
        state = trainer.init_compressed_state(cfg, jax.random.key(0), n_pods=2)
        step = jax.jit(trainer.make_compressed_train_step(cfg, ocfg, ratio=ratio))
    else:
        state = trainer.init_compressed_state(cfg, jax.random.key(0), n_pods=2)
        step = jax.jit(trainer.make_compressed_train_step(cfg, ocfg, ratio=1.0))

    losses, wire = [], 1.0
    for _ in range(steps):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        wire = float(m["wire_ratio"])
    return losses, wire


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--ratio", type=float, default=0.25)
    args = ap.parse_args()

    dense_losses, dense_wire = run(False, args.steps, 1.0)
    comp_losses, comp_wire = run(True, args.steps, args.ratio)
    print(f"{'step':>5} {'dense':>8} {'compressed':>11}")
    for i in range(0, args.steps, max(args.steps // 10, 1)):
        print(f"{i:>5} {dense_losses[i]:>8.3f} {comp_losses[i]:>11.3f}")
    print(f"\nfinal: dense {dense_losses[-1]:.3f} (wire ratio {dense_wire:.2f}) "
          f"vs compressed {comp_losses[-1]:.3f} (wire ratio {comp_wire:.3f})")
    print(f"cross-pod traffic cut to {comp_wire:.1%} with final-loss delta "
          f"{comp_losses[-1]-dense_losses[-1]:+.4f} (error feedback re-injects "
          f"dropped blocks; see tests/test_train_e2e.py for the lockstep check)")


if __name__ == "__main__":
    main()
