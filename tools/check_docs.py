"""Docs drift gate: ARCHITECTURE.md must cover every core/lake/serve module.

CI runs this so the documentation layer cannot silently rot as the code
grows: adding a public module under ``src/repro/core``, ``src/repro/lake``
or ``src/repro/serve`` without mentioning its path in the module index of
``docs/ARCHITECTURE.md`` fails the build, as does a README link to a
``docs/*.md`` file that does not exist.

Usage: ``python tools/check_docs.py`` from the repo root (CI does).
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
COVERED_PACKAGES = ("src/repro/core", "src/repro/lake", "src/repro/serve")


def public_modules() -> list:
    """Repo-relative paths of every public module in the covered layers."""
    out = []
    for pkg in COVERED_PACKAGES:
        for p in sorted((REPO / pkg).rglob("*.py")):
            if p.name.startswith("_"):
                continue  # __init__/private modules document their package
            out.append(p.relative_to(REPO).as_posix())
    return out


def main() -> int:
    """Check module-index coverage + README doc links; 0 = clean."""
    failures = []

    arch = REPO / "docs" / "ARCHITECTURE.md"
    if not arch.exists():
        print("FAIL: docs/ARCHITECTURE.md does not exist", file=sys.stderr)
        return 1
    text = arch.read_text()
    missing = [m for m in public_modules() if m not in text]
    for m in missing:
        failures.append(f"module {m} is missing from docs/ARCHITECTURE.md's "
                        f"module index")

    readme = (REPO / "README.md").read_text()
    linked = set(re.findall(r"\((docs/[\w./-]+\.md)\)", readme))
    for doc in ("docs/ARCHITECTURE.md", "docs/OPERATIONS.md",
                "docs/BENCHMARKS.md"):
        if doc not in linked:
            failures.append(f"README.md does not link to {doc}")
    for doc in sorted(linked):
        if not (REPO / doc).exists():
            failures.append(f"README.md links to {doc}, which does not exist")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        print(f"\n{len(failures)} docs check(s) failed", file=sys.stderr)
        return 1
    print(f"OK: {len(public_modules())} core/lake/serve modules covered by "
          f"docs/ARCHITECTURE.md; README doc links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
